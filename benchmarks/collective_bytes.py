"""Beyond-paper: token vs layer dataflow measured in REAL lowered HLO.

The paper compares its token dataflow to the layer dataflow inside its
simulator (Fig 8). Here we make the same comparison on the TPU mapping:
ring attention (shard_map + ppermute — the token dataflow) vs all-gather
attention (the layer dataflow), lowered on 8 host devices, with ICI bytes
parsed from the compiled HLO. The paper's 'binary before the bus'
compression insight is measured as the bf16-vs-f32 K/V transfer delta.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.parallel.ring_attention import (
    layer_dataflow_attention,
    ring_attention,
)
from repro.roofline import parse_collectives


N_SHARDS = 8


def _lower(fn, mesh, shapes, dtype):
    specs = (P(None, "sp"),) * 3
    sm = shard_map(fn, mesh=mesh, in_specs=specs, out_specs=P(None, "sp"))
    args = [jax.ShapeDtypeStruct(s, dtype) for s in shapes]
    return jax.jit(sm).lower(*args).compile()


def _ring(q, k, v):
    # the barrier keeps the K/V carry in its INPUT dtype on the wire —
    # without it XLA rewrites the scan carry to f32 (every use converts),
    # silently widening the ppermute payload
    k, v = jax.lax.optimization_barrier((k, v))
    return ring_attention(q, k, v, axis_name="sp")


def run() -> list[dict]:
    if jax.device_count() < 8:
        print("needs 8 devices — set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return []
    mesh = jax.make_mesh((8,), ("sp",))
    rows = []
    b, s, h, d = 2, 8192, 16, 128
    shapes = [(b, s, h, d)] * 3
    print(f"attention B={b} S={s} H={h} D={d} over {N_SHARDS}-way "
          f"sequence shard")
    print(f"{'dataflow':28s} {'ICI bytes/dev':>14s} {'ops':>24s}")
    for name, fn, dtype, loop_steps in [
        # ring permutes sit in a scan body: HLO counts them ONCE, the
        # ring executes them (n-1) times -> explicit correction factor
        ("token (ring, bf16 K/V)", _ring, jnp.bfloat16, N_SHARDS - 1),
        ("token (ring, f32 K/V)", _ring, jnp.float32, N_SHARDS - 1),
        ("layer (all-gather, bf16)",
         lambda q, k, v: layer_dataflow_attention(q, k, v,
                                                  axis_name="sp"),
         jnp.bfloat16, 1),
        ("layer (all-gather, f32)",
         lambda q, k, v: layer_dataflow_attention(q, k, v,
                                                  axis_name="sp"),
         jnp.float32, 1),
    ]:
        compiled = _lower(fn, mesh, shapes, dtype)
        st = parse_collectives(compiled.as_text())
        total = st.wire_bytes * loop_steps   # ring-weighted wire bytes
        print(f"{name:28s} {total/1e6:12.1f}MB {st.summary():>24s}"
              + (f" x{loop_steps} steps" if loop_steps > 1 else ""))
        rows.append({"dataflow": name, "ici_wire_bytes": total,
                     "ops": st.ops})
    if len(rows) == 4:
        r = rows[2]["ici_wire_bytes"] / max(rows[0]["ici_wire_bytes"], 1)
        print(f"\nring vs all-gather WIRE bytes: {r:.2f}x — equal totals "
              f"(both move the full K/V once past every device); the "
              f"token dataflow's win is OVERLAP: per-step permutes "
              f"pipeline behind score blocks while the bulk gather "
              f"serializes up front — the paper's Fig 6 argument.")
        print("bf16-vs-f32 wire: NOT measurable on the CPU backend "
              "(XLA:CPU legalizes bf16 carries/permutes to f32 — both "
              "rows show f32 payloads); on TPU the permute ships bf16, "
              "halving wire bytes (the paper's 'binary before the bus').")
    return rows


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    run()
