"""Benchmark orchestrator — one suite per paper table/figure.

PYTHONPATH=src python -m benchmarks.run [--only fig8,table5] [--skip table4]

Each suite prints its own comparison against the paper's reported numbers
and returns row dicts; a summary lands at the end. The dry-run roofline
table (EXPERIMENTS.md §Roofline) is built separately by
benchmarks.roofline_table from the cached dry-run sweep.

Wall-clock use here is intentional (suite runtimes for the summary
table) and carries `repro: allow[wall-clock-in-serve]` markers — the
virtual-clock contract applies to serve-layer logic, not to the
harness measuring the harness.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

SUITES = [
    ("table4", "benchmarks.table4_accuracy",
     "Table IV  — accuracy ladder FP32/Q8/Q8+SC"),
    ("table5", "benchmarks.table5_calibration",
     "Table V   — per-component calibration accuracy"),
    ("fig2", "benchmarks.fig2_breakdown",
     "Fig 2     — conventional-PIM time breakdown"),
    ("fig7", "benchmarks.fig7_momcap",
     "Fig 7     — MOMCAP accumulation linearity"),
    ("fig8", "benchmarks.fig8_dataflow",
     "Fig 8     — dataflow x pipelining sensitivity"),
    ("fig9_11", "benchmarks.fig9_11_comparison",
     "Figs 9-11 — platform comparison (published anchors)"),
    ("fig12", "benchmarks.fig12_scalability",
     "Fig 12    — sequence-length scalability"),
    ("kernels", "benchmarks.kernel_micro",
     "Kernels   — Pallas vs oracle + ladder accuracy"),
    ("collectives", "benchmarks.collective_bytes",
     "Beyond    — token vs layer dataflow in lowered HLO"),
    ("serve", "benchmarks.serve_throughput",
     "Beyond    — continuous-batching engine throughput"),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--skip", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    skip = set(args.skip.split(",")) if args.skip else set()

    results = {}
    for name, module, desc in SUITES:
        if only is not None and name not in only:
            continue
        if name in skip:
            continue
        print(f"\n{'='*72}\n{desc}\n{'='*72}")
        t0 = time.time()  # repro: allow[wall-clock-in-serve] -- benchmark harness timing, reported per suite
        try:
            mod = importlib.import_module(module)
            rows = mod.run()
            results[name] = ("ok", len(rows or []), time.time() - t0)  # repro: allow[wall-clock-in-serve] -- benchmark harness timing, reported per suite
        except Exception as e:
            traceback.print_exc()
            results[name] = ("FAIL: " + str(e)[:80], 0, time.time() - t0)  # repro: allow[wall-clock-in-serve] -- benchmark harness timing, reported per suite

    print(f"\n{'='*72}\nSUMMARY\n{'='*72}")
    for name, (status, n, dt) in results.items():
        print(f"  {name:12s} {status:12s} {n:4d} rows {dt:7.1f}s")
    if any(v[0].startswith("FAIL") for v in results.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
