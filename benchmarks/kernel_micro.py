"""Kernel micro-benchmarks: Pallas kernels vs pure-jnp oracles.

Correctness (allclose vs ref.py) + per-call wall time in interpret mode
(CPU container; on TPU the same code path compiles natively). Also prints
the ARTEMIS emulation ladder's accuracy at kernel level.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as quantlib
from repro.core.policy import ArithmeticPolicy
from repro.core.quantization import SC_LEVELS
from repro.kernels import attention_ref, flash_attention, sc_matmul, \
    sc_matmul_ref


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run() -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    print("== sc_matmul (ARTEMIS MAC pipeline) ==")
    for m, k, n in ((128, 160, 128), (256, 320, 256)):
        a = jax.random.normal(key, (m, k))
        b = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
        exact = a @ b
        sa = quantlib.quant_scale(a, 8)
        sb = quantlib.quant_scale(b, 8)
        aq, bq = quantlib.quantize(a, sa), quantlib.quantize(b, sb)
        for mode in ("int8", "artemis", "artemis_mxu"):
            pol = ArithmeticPolicy(mode=mode, ste=False)
            out = sc_matmul(a, b, pol)
            ref = sc_matmul_ref(aq, bq, mode=mode).astype(jnp.float32)
            ref = ref * sa * sb * (1 if mode == "int8" else SC_LEVELS)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)
            rel = float(jnp.linalg.norm(out - exact)
                        / jnp.linalg.norm(exact))
            us = _time(lambda: sc_matmul(a, b, pol))
            print(f"  {m}x{k}x{n} {mode:12s} kernel==oracle "
                  f"| vs fp32 rel {rel:.4f} | {us:9.0f} us/call(interp)")
            rows.append({"kernel": "sc_matmul", "shape": (m, k, n),
                         "mode": mode, "rel_err_fp32": rel, "us": us})

    print("== flash_attention (LSE online-softmax) ==")
    for b_, h, s, d in ((1, 4, 256, 64), (2, 8, 512, 64)):
        q = jax.random.normal(key, (b_, h, s, d))
        kk = jax.random.normal(jax.random.fold_in(key, 2), (b_, h, s, d))
        v = jax.random.normal(jax.random.fold_in(key, 3), (b_, h, s, d))
        o, lse = flash_attention(q, kk, v, causal=True, return_lse=True)
        o_ref, lse_ref = attention_ref(q, kk, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=2e-4, atol=2e-4)
        us = _time(lambda: flash_attention(q, kk, v, causal=True))
        print(f"  B{b_} H{h} S{s} D{d}: kernel==oracle | "
              f"{us:9.0f} us/call(interp)")
        rows.append({"kernel": "flash_attention",
                     "shape": (b_, h, s, d), "us": us})
    return rows


if __name__ == "__main__":
    run()
