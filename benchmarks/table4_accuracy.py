"""Table IV reproduction: model quality under FP32 / Q(8-bit) / Q(8-bit)+SC.

The paper fine-tunes five pre-trained transformers; offline we train small
models from scratch on deterministic learnable tasks and evaluate token
accuracy under the three arithmetic ladders (same model, same weights —
only inference arithmetic changes). The claim under test is the SHAPE:
  * int8 costs little vs FP32 (paper avg -0.9 points),
  * adding SC costs little vs int8 (paper avg -0.5 points).
One model per paper workload family, tasks of graded difficulty.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.data.pipeline import synthetic_task_batch
from repro.launch.steps import make_train_step
from repro.models import model
from repro.optim import OptimizerConfig, adamw_init

VOCAB = 64
TASKS = [
    ("transformer-base*", "copy", 12),
    ("bert-base*", "reverse", 12),
    ("albert-base*", "sort", 12),
    ("vit-base*", "modadd", 12),
    ("opt-350*", "copy", 24),       # longer-range variant
]
STEPS, BATCH = 600, 64

PAPER_ROWS = {
    "transformer-base*": (70.90, 70.40, 69.45),
    "bert-base*": (87.00, 86.27, 85.92),
    "albert-base*": (86.07, 84.80, 84.51),
    "vit-base*": (97.60, 96.50, 96.20),
    "opt-350*": (18.07, 17.79, 17.49),   # BLEU, shape-compared only
}


def _cfg() -> object:
    base = configs.get_config("qwen3_8b", smoke=True)
    return dataclasses.replace(base, vocab_size=VOCAB, vocab_round_to=16,
                               name="table4-lm")


def _train(cfg, task: str, n: int, seed: int = 0):
    params = model.init(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params)
    opt_cfg = OptimizerConfig(lr=3e-3, total_steps=STEPS, warmup_steps=30,
                              weight_decay=0.01)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    for step in range(STEPS):
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
        tokens, _ = synthetic_task_batch(key, task, BATCH, n, VOCAB)
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        params, opt, _ = step_fn(params, opt,
                                 {"tokens": tokens, "labels": labels})
    return params


def _accuracy(params, cfg, task: str, n: int, policy) -> float:
    correct = total = 0
    for i in range(8):
        key = jax.random.fold_in(jax.random.PRNGKey(12345), i)
        tokens, mask = synthetic_task_batch(key, task, BATCH, n, VOCAB)
        logits, _, _ = model.apply(params, cfg, {"tokens": tokens},
                                   policy=policy)
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        m = mask[:, 1:] > 0
        correct += int(jnp.sum((pred == tokens[:, 1:]) & m))
        total += int(jnp.sum(m))
    return 100.0 * correct / total


def run() -> list[dict]:
    ladders = [
        ("FP32", ArithmeticPolicy(mode="exact")),
        ("Q8", ArithmeticPolicy(mode="int8", ste=False)),
        ("Q8+SC", ArithmeticPolicy(mode="artemis_mxu", ste=False)),
    ]
    cfg = _cfg()
    rows = []
    print(f"{'model (task)':26s} {'FP32':>7s} {'Q8':>7s} {'Q8+SC':>7s}"
          f"   paper: FP32 / Q8 / Q8+SC")
    drops_q8, drops_sc = [], []
    for name, task, n in TASKS:
        params = _train(cfg, task, n)
        accs = {lbl: _accuracy(params, cfg, task, n, pol)
                for lbl, pol in ladders}
        p = PAPER_ROWS[name]
        print(f"{name+' ('+task+')':26s} {accs['FP32']:7.2f} "
              f"{accs['Q8']:7.2f} {accs['Q8+SC']:7.2f}   "
              f"{p[0]:.2f} / {p[1]:.2f} / {p[2]:.2f}")
        rows.append({"model": name, "task": task, **accs,
                     "paper": p})
        drops_q8.append(accs["FP32"] - accs["Q8"])
        drops_sc.append(accs["Q8"] - accs["Q8+SC"])
    avg_q8 = sum(drops_q8) / len(drops_q8)
    avg_sc = sum(drops_sc) / len(drops_sc)
    print(f"\navg drop FP32->Q8:   {avg_q8:+.2f} points (paper ~0.9)")
    print(f"avg drop Q8->Q8+SC:  {avg_sc:+.2f} points (paper ~0.5)")
    rows.append({"model": "AVG", "drop_q8": avg_q8, "drop_sc": avg_sc})
    return rows


if __name__ == "__main__":
    run()
