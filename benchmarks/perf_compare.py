"""Render the §Perf before/after comparison: baseline (frozen) vs the
optimized dry-run cache, per cell, with deltas.

PYTHONPATH=src python -m benchmarks.perf_compare
"""
from __future__ import annotations

import json
import os

HERE = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _rows(path, mesh="pod1_16x16"):
    with open(path) as f:
        cache = json.load(f)
    out = {}
    for row in cache.values():
        if row.get("mesh") == mesh and row.get("status") == "ok":
            out[(row["arch"], row["shape"])] = row
    return out


def run():
    base = _rows(os.path.join(HERE, "dryrun_baseline.json"))
    opt = _rows(os.path.join(HERE, "dryrun_cache.json"))
    rows = []
    print(f"{'cell':34s} {'t_mem b->o':>18s} {'t_coll b->o':>18s} "
          f"{'GiB b->o':>14s} {'roofl b->o':>14s}")
    for key in sorted(base):
        b = base[key]
        o = opt.get(key)
        if o is None:
            continue
        cell = f"{key[0]} x {key[1]}"
        same = abs(o.get("t_memory_s", 0) - b.get("t_memory_s", 0)) < 1e-12
        mark = "" if not same else "  (=baseline)"
        print(f"{cell:34s} "
              f"{b['t_memory_s']:8.2e}->{o['t_memory_s']:8.2e} "
              f"{b['t_collective_s']:8.2e}->{o['t_collective_s']:8.2e} "
              f"{b['bytes_per_device_gib']:6.1f}->"
              f"{o['bytes_per_device_gib']:6.1f} "
              f"{b['roofline_frac']:6.3f}->{o['roofline_frac']:6.3f}"
              + mark)
        rows.append({"cell": cell, "base": b, "opt": o})
    return rows


if __name__ == "__main__":
    run()
