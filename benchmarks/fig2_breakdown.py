"""Fig 2 reproduction: component-wise time on a conventional digital PIM
(DRISA: 1600 ns MUL) — the motivation figure. The paper's claim: >90% of
transformer execution time goes to the MatMuls in MHA + FFN.
"""
from __future__ import annotations

from repro.hwsim import paper_models, simulate_breakdown


def run() -> list[dict]:
    rows = []
    print(f"{'model':18s} {'matmul':>8s} {'softmax':>8s} {'nonlin':>8s} "
          f"{'move':>8s}")
    for name, w in paper_models().items():
        b = simulate_breakdown(w)
        print(f"{name:18s} {b['matmul']:8.1%} {b['softmax']:8.1%} "
              f"{b['nonlinear']:8.1%} {b['data_movement']:8.1%}")
        rows.append({"model": name, **b})
    ok = all(r["matmul"] > 0.9 for r in rows)
    print(f"\n>90% MatMul on all workloads: {ok} (paper: yes)")
    return rows


if __name__ == "__main__":
    run()
