"""Render the §Roofline table (EXPERIMENTS.md) from the dry-run cache.

PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod1_16x16]
[--markdown]
"""
from __future__ import annotations

import argparse
import json
import os

CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun_cache.json")

COLS = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
        "dominant", "useful_flop_frac", "roofline_frac",
        "bytes_per_device_gib", "fits_hbm", "collectives"]


def load_rows(mesh: str = "pod1_16x16", policy: str | None = None):
    with open(CACHE) as f:
        cache = json.load(f)
    rows = []
    for key, row in cache.items():
        if row.get("mesh") != mesh or row.get("status") != "ok":
            continue
        if policy is not None and f"|{policy}" not in key:
            continue
        rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def render(rows, markdown: bool = False) -> str:
    out = []
    if markdown:
        hdr = ("| arch | shape | t_comp | t_mem | t_coll | dominant | "
               "useful | roofline | GiB/dev | fits |")
        out.append(hdr)
        out.append("|" + "---|" * 10)
        for r in rows:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
                f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
                f"{r['dominant']} | {r['useful_flop_frac']:.2f} | "
                f"{r['roofline_frac']:.3f} | "
                f"{r['bytes_per_device_gib']:.2f} | "
                f"{'y' if r.get('fits_hbm') else 'N'} |")
    else:
        out.append(f"{'arch':20s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s}"
                   f" {'t_coll':>9s} {'dom':>6s} {'useful':>7s}"
                   f" {'roofl':>6s} {'GiB/dev':>8s} fits")
        for r in rows:
            out.append(
                f"{r['arch']:20s} {r['shape']:12s} "
                f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
                f"{r['t_collective_s']:9.2e} {r['dominant'][:6]:>6s} "
                f"{r['useful_flop_frac']:7.2f} {r['roofline_frac']:6.3f} "
                f"{r['bytes_per_device_gib']:8.2f} "
                f"{'y' if r.get('fits_hbm') else 'N'}")
    return "\n".join(out)


def run():
    rows = load_rows()
    print(render(rows))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1_16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.mesh)
    print(render(rows, markdown=args.markdown))


if __name__ == "__main__":
    main()
