"""Figs 9-11 reproduction: ARTEMIS vs CPU/GPU/TPU/FPGA/PIM accelerators.

The paper anchors these comparisons on PUBLISHED platform numbers (\"we
used power, latency, and energy values reported for the selected
accelerators\"). We do the same: hwsim produces ARTEMIS's absolute
latency/energy/efficiency per workload; platform anchors come from the
paper's reported average factors (hwsim.baselines). The claim under test
is the abstract's floor: >= 3.0x speedup, >= 1.8x energy, >= 1.9x
efficiency vs the BEST competitor.
"""
from __future__ import annotations

from repro.hwsim import BASELINES, DataflowConfig, paper_models, \
    simulate_model
from repro.hwsim.baselines import HEADLINE


def run() -> list[dict]:
    rows = []
    ms = paper_models()
    print("ARTEMIS absolute numbers (hwsim, token_PP):")
    print(f"{'model':18s} {'latency':>10s} {'energy':>10s} "
          f"{'GOPS':>8s} {'GOPS/W':>8s}")
    for name, w in ms.items():
        r = simulate_model(w, DataflowConfig(scheme="token_PP"))
        gops_w = r.gops / 60.0     # the 60 W budget
        print(f"{name:18s} {r.latency_ns/1e6:8.2f}ms "
              f"{r.energy_pj/1e9:8.2f}mJ {r.gops:8.0f} {gops_w:8.0f}")
        rows.append({"model": name, "latency_ms": r.latency_ns / 1e6,
                     "energy_mj": r.energy_pj / 1e9, "gops": r.gops,
                     "gops_per_w": gops_w})

    print("\nvs platforms (paper-published anchors, avg factors):")
    print(f"{'platform':10s} {'speedup':>9s} {'energy':>9s} "
          f"{'efficiency':>11s}")
    best = {"speedup": 1e30, "energy": 1e30, "efficiency": 1e30}
    for b in BASELINES.values():
        print(f"{b.name:10s} {b.speedup_vs:8.1f}x {b.energy_vs:8.1f}x "
              f"{b.efficiency_vs:10.1f}x"
              + ("   (BERT-family only)" if b.bert_only else ""))
        rows.append({"platform": b.name, "speedup": b.speedup_vs,
                     "energy": b.energy_vs, "efficiency": b.efficiency_vs})
        best["speedup"] = min(best["speedup"], b.speedup_vs)
        best["energy"] = min(best["energy"], b.energy_vs)
        best["efficiency"] = min(best["efficiency"], b.efficiency_vs)

    print("\nheadline floor (abstract): "
          f"speedup {best['speedup']:.1f}x >= {HEADLINE['speedup']}x, "
          f"energy {best['energy']:.1f}x >= {HEADLINE['energy']}x, "
          f"efficiency {best['efficiency']:.1f}x >= "
          f"{HEADLINE['efficiency']}x")
    ok = (best["speedup"] >= HEADLINE["speedup"]
          and best["energy"] >= HEADLINE["energy"]
          and best["efficiency"] >= HEADLINE["efficiency"])
    print(f"headline holds: {ok}")
    rows.append({"headline_holds": ok, **best})
    return rows


if __name__ == "__main__":
    run()
