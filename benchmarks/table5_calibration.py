"""Table V reproduction: per-component calibration accuracy.

For each approximate block (stochastic MUL, analog ACC, A_to_B, softmax)
we measure MAE / max error normalized to the block's full scale, plus the
paper's "calibration accuracy" metric. Reverse-engineering Table V shows
calibration accuracy == -log2(MAE) exactly (2^-4.68 = 0.039,
2^-6.88 = 0.0085, 2^-11.38 = 0.00037), so we report that.

Our deterministic implementation gives the IDEAL-DIGITAL error floor; the
paper's values are SPICE-measured and include analog non-idealities. The
`sigma_analog` knob reproduces the paper's ACC row when set to
MAE_paper / sqrt(2/pi) (Gaussian readout noise); the MUL gap (ours 10x
lower) is the analog AND margin we deliberately do not model — recorded
in EXPERIMENTS.md §Table V.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import (
    MomcapConfig, artemis_softmax, readout_quantize, sc_multiply,
)


def _calib(mae: float) -> float:
    return -math.log2(max(mae, 1e-12))


def mul_errors() -> dict:
    """Stochastic MUL over the full 128x128 operand square (exact sweep)."""
    a = jnp.arange(128)
    b = jnp.arange(128)
    prod_exact = (a[:, None] * b[None, :]).astype(jnp.float32) / 128.0
    prod_sc = sc_multiply(a[:, None], b[None, :]).astype(jnp.float32)
    err = jnp.abs(prod_sc - prod_exact) / 127.0   # normalize to full scale
    mae = float(jnp.mean(err))
    return {"mae": mae, "max": float(jnp.max(err)), "calib_bits": _calib(mae)}


def acc_errors(n_trials: int = 4096, sigma: float = 0.0) -> dict:
    """Analog ACC: group-of-20 accumulation + 8-bit quantizing readout,
    optional Gaussian analog noise (the paper's measured non-ideality)."""
    cfg = MomcapConfig(acc_depth=20, readout_bits=8, sigma_analog=sigma)
    key = jax.random.PRNGKey(0)
    prods = jax.random.randint(key, (n_trials, 20), 0, 128)
    exact = jnp.sum(prods, axis=-1).astype(jnp.float32)
    ro = readout_quantize(exact, cfg,
                          jax.random.PRNGKey(1) if sigma > 0 else None)
    err = jnp.abs(ro - exact) / cfg.full_scale
    mae = float(jnp.mean(err))
    return {"mae": mae, "max": float(jnp.max(err)), "calib_bits": _calib(mae)}


def a_to_b_errors() -> dict:
    """A_to_B ladder: comparator-ladder quantization of one analog value
    (the conversion path alone, fine input grid)."""
    cfg = MomcapConfig(acc_depth=20, readout_bits=8)
    xs = jnp.linspace(0.0, cfg.full_scale, 100001)
    ro = readout_quantize(xs, cfg)
    err = jnp.abs(ro - xs) / cfg.full_scale
    mae = float(jnp.mean(err))
    return {"mae": mae, "max": float(jnp.max(err)), "calib_bits": _calib(mae)}


def softmax_errors(n_trials: int = 64) -> dict:
    key = jax.random.PRNGKey(1)
    y = jax.random.normal(key, (n_trials, 64)) * 4.0
    ref = jax.nn.softmax(y, axis=-1)
    lut = artemis_softmax(y, axis=-1, n_in=256, out_bits=8)
    err = jnp.abs(lut - ref)                      # prob units = full scale
    mae = float(jnp.mean(err))
    return {"mae": mae, "max": float(jnp.max(err)), "calib_bits": _calib(mae)}


PAPER = {
    "Stochastic MUL": (0.039, 0.123, 4.68),
    "Analog ACC": (0.0085, 0.0729, 6.88),
    "A_to_B": (0.00037, 0.00062, 11.38),
    "Softmax": (0.0020, 0.0078, 8.20),
}

# Gaussian sigma that reproduces the paper's measured ACC MAE:
# E|N(0, s)| = s*sqrt(2/pi) -> s = 0.0085 / 0.7979
ACC_SIGMA_CALIBRATED = 0.0085 / math.sqrt(2.0 / math.pi)


def run() -> list[dict]:
    ours = {
        "Stochastic MUL": mul_errors(),
        "Analog ACC": acc_errors(),
        "A_to_B": a_to_b_errors(),
        "Softmax": softmax_errors(),
    }
    acc_cal = acc_errors(sigma=ACC_SIGMA_CALIBRATED)
    rows = []
    print(f"{'Block':18s} {'MAE':>9s} {'paper':>9s} {'Max':>9s} "
          f"{'paper':>9s} {'Calib':>6s} {'paper':>6s}")
    for name, o in ours.items():
        p = PAPER[name]
        print(f"{name:18s} {o['mae']:9.5f} {p[0]:9.5f} {o['max']:9.5f} "
              f"{p[1]:9.5f} {o['calib_bits']:6.2f} {p[2]:6.2f}")
        rows.append({"block": name, **o, "paper_mae": p[0],
                     "paper_max": p[1], "paper_calib": p[2]})
    print(f"{'ACC (noise-cal.)':18s} {acc_cal['mae']:9.5f} "
          f"{PAPER['Analog ACC'][0]:9.5f} {acc_cal['max']:9.5f} "
          f"{PAPER['Analog ACC'][1]:9.5f} {acc_cal['calib_bits']:6.2f} "
          f"{PAPER['Analog ACC'][2]:6.2f}")
    rows.append({"block": "Analog ACC (noise-calibrated)", **acc_cal,
                 "paper_mae": PAPER["Analog ACC"][0],
                 "paper_max": PAPER["Analog ACC"][1],
                 "paper_calib": PAPER["Analog ACC"][2]})
    return rows


if __name__ == "__main__":
    run()
