"""Fig 7 reproduction: MOMCAP charge-accumulation linearity vs capacitance.

The RC charge model (repro.core.analog): each 128-bit accumulation event
adds dv = (Q/C)(1 - v/V_SAT); the staircase stays "linear" while the step
exceeds 95% of the first step. The paper selects 8 pF (tile-area-matched,
338 um^2) => 20 linear accumulations.
"""
from __future__ import annotations

import numpy as np

from repro.core import max_linear_accumulations, momcap_voltage_trace


def run() -> list[dict]:
    rows = []
    print(f"{'C (pF)':>7s} {'max linear accs':>16s} {'V @ 20 accs':>12s}")
    for c_pf in (4, 8, 12, 16, 24, 32, 40):
        n = max_linear_accumulations(c_pf)
        trace = np.asarray(momcap_voltage_trace(c_pf, 40))
        rows.append({"c_pf": c_pf, "max_linear": n,
                     "v20": float(trace[19])})
        print(f"{c_pf:7d} {n:16d} {rows[-1]['v20']:12.3f}")
    # the paper's design point
    n8 = max_linear_accumulations(8.0)
    print(f"\n8 pF supports {n8} linear accumulations "
          f"(paper: 20, tile-area-matched)")
    assert n8 == 20, n8
    return rows


if __name__ == "__main__":
    run()
